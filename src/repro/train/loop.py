"""Fault-tolerant training loop: checkpoint/resume, watchdog, injection.

The loop is deliberately plain: a production job wraps exactly this shape —
build step -> restore-or-init -> iterate(data) with watchdog ->
checkpoint cadence -> on failure: resume from latest (same or smaller mesh).
"""

from __future__ import annotations

import dataclasses
import logging
import time
from typing import Callable, Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig, ParallelConfig, TrainConfig
from repro.data.pipeline import DataConfig, make_batch_iterator
from repro.models.model import LM
from repro.obs import Registry, Tracer
from repro.train.checkpoint import CheckpointManager
from repro.train.fault_tolerance import FailureInjector, StepTimeout, Watchdog
from repro.train.step import make_train_state, make_train_step, shard_state

log = logging.getLogger(__name__)

__all__ = ["TrainResult", "run_training"]


@dataclasses.dataclass
class TrainResult:
    final_step: int
    losses: list
    resumed_from: Optional[int]
    interrupted: bool = False
    registry: Optional[Registry] = None   # step metrics (repro.obs)
    tracer: Optional[Tracer] = None       # step/checkpoint spans


def _batch_tokens(batch) -> int:
    """Token count of one batch (throughput accounting): the ``tokens``
    leaf when present, else the largest integer leaf's element count."""
    if isinstance(batch, dict):
        if "tokens" in batch:
            return int(np.prod(np.shape(batch["tokens"])))
        sizes = [
            int(np.prod(np.shape(v)))
            for v in batch.values()
            if np.issubdtype(np.asarray(v).dtype, np.integer)
        ]
        return max(sizes, default=0)
    return 0


def run_training(
    lm: LM,
    tcfg: TrainConfig,
    pcfg: ParallelConfig,
    mesh,
    *,
    steps: Optional[int] = None,
    data_cfg: Optional[DataConfig] = None,
    injector: Optional[FailureInjector] = None,
    step_timeout_s: float = 0.0,
    log_every: int = 10,
    make_batch: Optional[Callable[[int], dict]] = None,
    registry: Optional[Registry] = None,
    tracer: Optional[Tracer] = None,
) -> TrainResult:
    steps = steps or tcfg.total_steps
    ckpt = CheckpointManager(tcfg.checkpoint_dir, keep=tcfg.keep_checkpoints)

    # Telemetry (repro.obs): per-step time/loss/grad-norm metrics and
    # step/checkpoint spans. Defaults to private instances returned on the
    # TrainResult; recording is in-process only (export is the caller's
    # sink decision, e.g. launch/train --metrics-out).
    obs = registry if registry is not None else Registry()
    tr = tracer if tracer is not None else Tracer()
    m_steps = obs.counter("train.steps")
    m_tokens = obs.counter("train.tokens")
    m_retries = obs.counter("train.steps", event="watchdog_retry")
    m_step_time = obs.histogram("train.step_time_s")
    g_loss = obs.gauge("train.loss")
    g_gnorm = obs.gauge("train.grad_norm")
    g_lr = obs.gauge("train.lr")
    g_tput = obs.gauge("train.throughput_tokens_per_s")

    with jax.set_mesh(mesh):
        state = make_train_state(lm, tcfg, jax.random.PRNGKey(tcfg.seed))
        resumed_from = None
        if ckpt.latest_step() is not None:
            with tr.span("train.restore"):
                state, resumed = ckpt.restore_latest(state)
            resumed_from = resumed
            log.info("resumed from step %d", resumed)
        state = shard_state(state, pcfg, mesh)
        start = resumed_from + 1 if resumed_from is not None else 0

        if make_batch is None:
            assert data_cfg is not None
            src = make_batch_iterator(data_cfg, start_step=start)
            batch_fn = lambda step: next(iter(src))
        else:
            batch_fn = make_batch

        step_fn, compile_step = make_train_step(lm, tcfg, pcfg, mesh)
        batch0 = batch_fn(start)
        with tr.span("train.compile"):
            compiled = compile_step(state, batch0)

        losses = []
        interrupted = False
        t0 = time.time()
        i = start
        while i < steps:
            batch = batch_fn(i) if i != start else batch0
            t_step = time.perf_counter()
            try:
                if injector is not None:
                    injector.maybe_fail(i)
                # The span closes after float(loss) blocks, so it covers
                # real device step time, not the async dispatch.
                with tr.span("train.step", step=i):
                    if step_timeout_s > 0:
                        with Watchdog(step_timeout_s):
                            state, metrics = compiled(state, batch)
                            loss = float(metrics["loss"])  # blocks inside watchdog
                    else:
                        state, metrics = compiled(state, batch)
                        loss = float(metrics["loss"])
            except StepTimeout:
                log.warning("step %d hit watchdog; re-running batch", i)
                tr.instant("train.watchdog_retry", step=i)
                m_retries.inc()
                continue  # straggler mitigation: redo the step
            except RuntimeError as e:
                log.error("step %d failed: %s — checkpoint + stop", i, e)
                tr.instant("train.failure", step=i)
                interrupted = True
                break
            dt_step = time.perf_counter() - t_step
            n_tok = _batch_tokens(batch)
            m_steps.inc()
            m_tokens.inc(n_tok)
            m_step_time.observe(dt_step)
            g_loss.set(loss)
            if "grad_norm" in metrics:
                g_gnorm.set(float(metrics["grad_norm"]))
            if "lr" in metrics:
                g_lr.set(float(metrics["lr"]))
            if dt_step > 0 and n_tok:
                g_tput.set(n_tok / dt_step)
            losses.append(loss)
            if not np.isfinite(loss):
                raise FloatingPointError(f"non-finite loss at step {i}: {loss}")
            if log_every and i % log_every == 0:
                dt = time.time() - t0
                log.info("step %d loss %.4f (%.2fs elapsed)", i, loss, dt)
            if tcfg.checkpoint_every and (i + 1) % tcfg.checkpoint_every == 0:
                with tr.span("train.checkpoint", step=i):
                    ckpt.save(state, i)
            i += 1

        with tr.span("train.checkpoint", step=max(i - 1, 0), final=True):
            ckpt.save(state, max(i - 1, 0), blocking=True)
        return TrainResult(
            final_step=i - 1,
            losses=losses,
            resumed_from=resumed_from,
            interrupted=interrupted,
            registry=obs,
            tracer=tr,
        )
