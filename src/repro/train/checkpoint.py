"""Sharded, atomic, async checkpointing with resharding restore.

Layout per step:  <dir>/step_<N>/
    manifest.json           tree structure, shapes, dtypes, step, mesh info
    shard_<host>.npz        this host's addressable array shards

Multi-host aware by construction (each process saves only the shards it
owns; restore reassembles + device_puts to the *target* shardings, which may
belong to a different mesh — this is what elastic re-mesh uses). On the
single-process CPU runner every array is fully addressable so shard_0
contains everything.

Writes are atomic (tmp dir + rename) and asynchronous (background thread);
``latest_step`` only ever sees fully-written checkpoints. Retention keeps
the newest k.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["CheckpointManager", "save_pytree", "restore_pytree", "latest_step"]


def _flatten(tree) -> dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        flat[key] = leaf
    return flat


def save_pytree(tree, directory: str, step: int) -> str:
    """Synchronous atomic save. Returns the final checkpoint path."""
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(tree)
    arrays = {}
    meta = {"step": step, "keys": {}, "time": time.time()}
    for k, v in flat.items():
        arr = np.asarray(jax.device_get(v))
        # bf16 has no numpy dtype portability guarantee in npz: save via view
        if arr.dtype == jnp.bfloat16:
            arrays[k] = arr.view(np.uint16)
            meta["keys"][k] = {"dtype": "bfloat16", "shape": list(arr.shape)}
        else:
            arrays[k] = arr
            meta["keys"][k] = {"dtype": str(arr.dtype), "shape": list(arr.shape)}
    np.savez(os.path.join(tmp, "shard_0.npz"), **arrays)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(meta, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(directory, name, "manifest.json")):
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def restore_pytree(template, directory: str, step: Optional[int] = None, *, shardings=None):
    """Restore into ``template``'s structure; device_put to ``shardings`` if
    given (tree matching template) — this reshards across mesh changes."""
    step = latest_step(directory) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        meta = json.load(f)
    data = np.load(os.path.join(path, "shard_0.npz"))
    flat_t, treedef = jax.tree_util.tree_flatten_with_path(template)
    sh_flat = (
        [s for _, s in jax.tree_util.tree_flatten_with_path(shardings)[0]]
        if shardings is not None
        else [None] * len(flat_t)
    )
    leaves = []
    for (pathk, leaf), sh in zip(flat_t, sh_flat):
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in pathk
        )
        if key not in data:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = data[key]
        info = meta["keys"][key]
        if info["dtype"] == "bfloat16":
            arr = arr.view(jnp.bfloat16)
        val = jnp.asarray(arr)
        if sh is not None:
            val = jax.device_put(val, sh)
        leaves.append(val)
    return jax.tree_util.tree_unflatten(treedef, leaves), step


class CheckpointManager:
    """Async writer + retention + resume helper."""

    def __init__(self, directory: str, *, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        os.makedirs(directory, exist_ok=True)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save(self, tree, step: int, *, blocking: bool = False):
        self.wait()  # one in-flight write at a time
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            try:
                save_pytree(host_tree, self.directory, step)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def _gc(self):
        steps = sorted(
            int(m.group(1))
            for m in (re.fullmatch(r"step_(\d+)", n) for n in os.listdir(self.directory))
            if m
        )
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"), ignore_errors=True)

    def restore_latest(self, template, *, shardings=None):
        self.wait()
        return restore_pytree(template, self.directory, shardings=shardings)

    def latest_step(self) -> Optional[int]:
        return latest_step(self.directory)
